"""The paper's algorithm as MoE routing — visual demo.

Shows that expert dispatch IS sparse assembly: the (token, expert, gate)
triplets run through the same Part-1/Part-2 counting machinery as the
Matlab `sparse` reproduction, and the combine is the duplicate-summing
post-processing.

    PYTHONPATH=src python examples/moe_dispatch_demo.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.moe import init_moe, moe_dispatch_indices, moe_ffn

cfg = get_config("olmoe_1b_7b").reduced(d_model=64, dtype="float32")
E, K = cfg.moe.n_experts, cfg.moe.top_k
print(f"OLMoE-style reduced MoE: {E} experts, top-{K}")

rng = np.random.default_rng(0)
params = init_moe(jax.random.key(0), cfg)
x = jnp.asarray(rng.normal(size=(2, 16, 64)), jnp.float32)

# --- routing triplets: exactly the paper's (i, j, s) -------------------
logits = jnp.einsum("bsd,de->bse", x, params["router"])
gates, experts = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
T = 2 * 16
print(f"routing produced {T * K} triplets (token, expert, gate) — "
      f"this is COO data with {E} columns")

# --- Part 1+2: histogram + counting-sort placement ---------------------
slot, load = moe_dispatch_indices(
    experts.reshape(-1).astype(jnp.int32), n_experts=E,
    capacity=int(1.25 * K * T / E),
)
print("expert load histogram (Part 1, private counters):")
print("  ", np.asarray(load))
drops = int(jnp.sum(slot >= E * int(1.25 * K * T / E)))
print(f"capacity-cropped (the 'nzmax' overflow): {drops} / {T * K}")

# --- the full layer: dispatch -> expert FFNs -> duplicate-summing combine
y, aux = moe_ffn(params, x, cfg)
print(f"combine output: {y.shape}, aux load-balance loss {float(aux):.4f}")

# --- exactness: compare one token against looping over its experts -----
t = 5
xt = x.reshape(T, 64)[t]
yref = np.zeros(64)
for kk in range(K):
    e = int(experts.reshape(T, K)[t, kk])
    g = float((gates / gates.sum(-1, keepdims=True)).reshape(T, K)[t, kk])
    hg = np.asarray(xt) @ np.asarray(params["gate_ein"])[e]
    hu = np.asarray(xt) @ np.asarray(params["up_ein"])[e]
    act = hg / (1 + np.exp(-hg)) * hu
    yref += g * (act @ np.asarray(params["down_eout"])[e])
err = np.abs(np.asarray(y).reshape(T, 64)[t] - yref).max()
print(f"token {t}: fsparse-dispatch vs per-expert loop err = {err:.2e}")
assert err < 1e-4
print("OK — MoE dispatch is the paper's assembly, end to end.")
