"""End-to-end training driver: a ~100M-parameter LM, few hundred steps.

This is the deliverable-(b) end-to-end example.  The default preset is
a ~108M dense model (olmo-family: 8L x d768 x 12H, vocab 50304, seq 512)
trained for 300 steps with the full production stack: sharded params,
microbatch accumulation, bf16 grad compression + error feedback, AdamW,
async checkpointing, resumable data pipeline.

On a TPU slice this preset runs as-is (the launcher picks up all local
devices).  On the CPU CI container use ``--preset tiny`` (~1.5M params)
which finishes in ~2 minutes; ``--preset full`` is the 100M run.

    PYTHONPATH=src python examples/train_lm.py --preset tiny
    PYTHONPATH=src python examples/train_lm.py --preset full --steps 300
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch import train as train_launch


PRESETS = {
    # ~108M params: 8L d768 12H ff3072 vocab 50304 (tied embeddings)
    "full": dict(n_layers=8, d_model=768, n_heads=12, n_kv_heads=12,
                 d_ff=3072, vocab=50304, head_dim=64),
    # ~14M params: CI-scale but same code path
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
                  d_ff=1024, vocab=8192, head_dim=32),
    # ~1.5M params: smoke
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                 d_ff=512, vocab=2048, head_dim=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    overrides = PRESETS[args.preset]
    base = get_config("olmo_1b")
    cfg = dataclasses.replace(base, **overrides)
    n = cfg.n_params
    print(f"[example] preset={args.preset}: ~{n/1e6:.1f}M params")

    steps = args.steps or {"full": 300, "small": 300, "tiny": 200}[args.preset]
    batch = args.batch or {"full": 32, "small": 16, "tiny": 8}[args.preset]
    seq = args.seq or {"full": 512, "small": 256, "tiny": 128}[args.preset]

    # reuse the production launcher by monkey-pointing its config lookup
    import repro.configs as configs_mod
    orig = configs_mod.get_config
    configs_mod.get_config = lambda name: cfg if name == "example" else orig(name)
    train_launch.get_config = configs_mod.get_config
    try:
        return train_launch.main([
            "--arch", "example",
            "--steps", str(steps),
            "--batch", str(batch),
            "--seq", str(seq),
            "--microbatches", "2",
            "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "100",
            "--log-every", "20",
        ])
    finally:
        configs_mod.get_config = orig


if __name__ == "__main__":
    sys.exit(main())
