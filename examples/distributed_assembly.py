"""Distributed sparse assembly across 8 devices (paper §3 at mesh scale).

Self-re-executes with XLA_FLAGS for 8 host devices (the flag must be
set before jax initializes).  Shows the three phases of the distributed
algorithm: per-device histograms + psum (Part 1), capacity-bounded
all_to_all routing to row-block owners, local assembly per device —
then a distributed SpMV on the block-row result.

    PYTHONPATH=src python examples/distributed_assembly.py
"""
import os
import sys

if os.environ.get("_REPRO_DIST_DEMO") != "1":
    env = dict(os.environ)
    env["_REPRO_DIST_DEMO"] = "1"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execve(sys.executable, [sys.executable] + sys.argv, env)

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import (
    make_distributed_assemble,
    make_distributed_spmv,
)
from repro.core.oracle import dense_oracle
from repro.core.ransparse import ransparse
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(data=8, model=1)
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

M = N = 512
ii, jj, ss, _ = ransparse(M, 12, 2, seed=0)
rng = np.random.default_rng(1)
ss = rng.normal(size=ii.shape)
rows = (ii - 1).astype(np.int32)
cols = (jj - 1).astype(np.int32)
vals = ss.astype(np.float32)
print(f"{len(rows)} raw triplets -> {M}x{N} matrix, "
      f"sharded over the 'data' axis ({len(rows)//8} per device)")

sh = NamedSharding(mesh, P("data"))
assemble = make_distributed_assemble(mesh, M=M, N=N, capacity_factor=3.0)
A, overflow = assemble(
    jax.device_put(rows, sh), jax.device_put(cols, sh),
    jax.device_put(vals, sh),
)
print(f"assembled: {A.n_blocks} row blocks x {A.rows_per_block} rows, "
      f"per-block nnz = {np.asarray(A.nnz).tolist()}")
print(f"capacity overflow: {bool(overflow)}")

ref = dense_oracle(rows, cols, vals, M, N)
err = np.abs(np.asarray(A.to_dense()) - ref).max()
print(f"max err vs dense oracle: {err:.2e}")

spmv = make_distributed_spmv(mesh, M=M, N=N)
x = rng.normal(size=N).astype(np.float32)
y = np.asarray(spmv(A, jnp.asarray(x)))
err2 = np.abs(y - ref @ x).max()
print(f"distributed spmv err: {err2:.2e}")
assert err < 1e-4 and err2 < 1e-3
print("OK")
