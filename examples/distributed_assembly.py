"""Distributed sparse assembly across 8 devices (paper §3 at mesh scale).

Self-re-executes with XLA_FLAGS for 8 host devices (the flag must be
set before jax initializes).  Shows the sharded two-phase split: one
``plan_sharded`` call runs Phase A (per-device histograms + psum +
exclusive device scan), Phase B (capacity-bounded all_to_all routing)
and Phase C (per-row-block symbolic assembly); every subsequent
``assemble`` is only the O(L/p) value shuffle + collision-free scatter.
Then a distributed SpMV on the block-row result.

    PYTHONPATH=src python examples/distributed_assembly.py
"""
import os
import sys
import time

if os.environ.get("_REPRO_DIST_DEMO") != "1":
    env = dict(os.environ)
    env["_REPRO_DIST_DEMO"] = "1"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execve(sys.executable, [sys.executable] + sys.argv, env)

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.oracle import dense_oracle
from repro.core.ransparse import ransparse
from repro.launch.mesh import make_data_mesh
from repro.sparse import convert, nnz_of, plan_sharded

mesh = make_data_mesh()
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

M = N = 512
ii, jj, ss, _ = ransparse(M, 12, 2, seed=0)
rng = np.random.default_rng(1)
rows = (ii - 1).astype(np.int32)
cols = (jj - 1).astype(np.int32)
vals = rng.normal(size=ii.shape).astype(np.float32)
print(f"{len(rows)} raw triplets -> {M}x{N} matrix, "
      f"sharded over the 'data' axis ({len(rows)//8} per device)")

# --- symbolic phase: Phases A-C, once --------------------------------------
t0 = time.perf_counter()
pat = plan_sharded(rows, cols, (M, N), mesh=mesh, capacity_factor=3.0)
jax.block_until_ready(pat.send_slot)
print(f"planned in {1e3*(time.perf_counter()-t0):.1f} ms: "
      f"p={pat.p}, capacity={pat.capacity}/bucket, "
      f"block loads = {np.asarray(pat.block_load[0]).tolist()}")
print(f"capacity overflow: {bool(pat.any_overflow())}")

# --- numeric phase: O(L/p) fills, many times -------------------------------
A = pat.assemble(jnp.asarray(vals))
print(f"assembled: {A.n_blocks} row blocks x {A.rows_per_block} rows, "
      f"per-block nnz = {np.asarray(A.nnz).tolist()} "
      f"(total {nnz_of(A)})")

ref = dense_oracle(rows, cols, vals, M, N)
err = np.abs(np.asarray(A.to_dense()) - ref).max()
print(f"max err vs dense oracle: {err:.2e}")

vals2 = rng.normal(size=ii.shape).astype(np.float32)
A2 = pat.assemble(jnp.asarray(vals2))     # same structure, new values
ref2 = dense_oracle(rows, cols, vals2, M, N)
err_reuse = np.abs(np.asarray(A2.to_dense()) - ref2).max()
print(f"plan-reuse fill err: {err_reuse:.2e}")

# --- consumers: sharded SpMV + registry conversion -------------------------
x = rng.normal(size=N).astype(np.float32)
y = np.asarray(A @ jnp.asarray(x))        # per-block shared CSC kernel tail
err2 = np.abs(y - ref @ x).max()
print(f"distributed spmv err: {err2:.2e}")

C = convert(A, "csc")                     # block-row -> Matlab layout
err3 = np.abs(np.asarray(C.to_dense()) - ref).max()
print(f"convert(A, 'csc') err: {err3:.2e}")

assert err < 1e-4 and err_reuse < 1e-4 and err2 < 1e-3 and err3 < 1e-4
print("OK")
