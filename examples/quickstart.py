"""Quickstart: Matlab-compatible sparse assembly in JAX.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import fsparse, spmv
from repro.core.oracle import dense_oracle

# --- the paper's running example (Listing 1) ---------------------------
s = [4, 4, 5, 7, 3, 5, 5, 4, 3, 4, 9, 7, -2]
i = [3, 4, 1, 3, 2, 1, 4, 4, 4, 3, 2, 3, 1]
j = [3, 3, 1, 4, 1, 1, 4, 3, 1, 3, 2, 2, 4]

S = fsparse(i, j, s)                      # size implied, duplicates summed
print("dense:\n", np.asarray(S.to_dense()))
print("nnz:", int(S.nnz))
print("jcS:", np.asarray(S.indptr))       # [0 3 5 7 10] — as in §2.3.4

# --- a bigger random assembly, checked against a dense oracle ----------
rng = np.random.default_rng(0)
L, M, N = 50_000, 2_000, 1_500
ii = rng.integers(1, M + 1, L)
jj = rng.integers(1, N + 1, L)
ss = rng.normal(size=L)
A = fsparse(ii, jj, ss, (M, N))
ref = dense_oracle(ii - 1, jj - 1, ss, M, N)
err = np.abs(np.asarray(A.to_dense()) - ref).max()
print(f"assembled {L} triplets -> nnz={int(A.nnz)}, max err vs oracle {err:.2e}")

# --- the matrix is immediately usable: y = A @ x ------------------------
x = jnp.ones((N,), jnp.float32)
y = spmv(A, x)
print("spmv check:", np.abs(np.asarray(y) - ref @ np.ones(N)).max())

# --- index-expansion extension (outer-product assembly, §2.1) -----------
E = fsparse([[1], [2], [3]], [1, 2], 7.0, (3, 2))
print("expanded:\n", np.asarray(E.to_dense()))
