"""Quickstart: Matlab-compatible sparse assembly in JAX, two-phase API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.sparse import CSR, convert, find, fsparse, nnz_of, plan, spmv
from repro.core.oracle import dense_oracle

# --- the paper's running example (Listing 1), Matlab facade ------------
s = [4, 4, 5, 7, 3, 5, 5, 4, 3, 4, 9, 7, -2]
i = [3, 4, 1, 3, 2, 1, 4, 4, 4, 3, 2, 3, 1]
j = [3, 3, 1, 4, 1, 1, 4, 3, 1, 3, 2, 2, 4]

S = fsparse(i, j, s)                      # size implied, duplicates summed
print("dense:\n", np.asarray(S.to_dense()))
print("nnz:", nnz_of(S))
print("jcS:", np.asarray(S.indptr))       # [0 3 5 7 10] — as in §2.3.4
fi, fj, fv = find(S)                      # Matlab [i,j,v] = find(S)
print("find:", fi.tolist(), fj.tolist(), fv.tolist())

# --- two-phase API: plan once, assemble many ----------------------------
# The FEM workflow: the mesh (sparsity pattern) is fixed, element values
# change every step.  plan() runs the paper's Parts 1-4 once; assemble()
# is only the O(L) gather + collision-free scatter — no sorting.
rng = np.random.default_rng(0)
L, M, N = 50_000, 2_000, 1_500
rows = rng.integers(0, M, L).astype(np.int32)
cols = rng.integers(0, N, L).astype(np.int32)

pat = plan(rows, cols, (M, N))            # symbolic phase (once)
for step in range(3):                     # numeric phase (many times)
    vals = rng.normal(size=L).astype(np.float32)
    A = pat.assemble(vals)
    ref = dense_oracle(rows, cols, vals, M, N)
    err = np.abs(np.asarray(A.to_dense()) - ref).max()
    print(f"step {step}: reassembled nnz={int(A.nnz)}, "
          f"max err vs oracle {err:.2e}")

# batched numeric phase: many value vectors, one structure
vb = rng.normal(size=(4, L)).astype(np.float32)
Ab = pat.assemble_batch(vb)
print("batched data shape:", Ab.data.shape)

# --- the matrix is immediately usable: y = A @ x ------------------------
x = jnp.ones((N,), jnp.float32)
y = spmv(A, x)
print("spmv check:", np.abs(np.asarray(y) - ref @ np.ones(N)).max())

# --- format zoo: one protocol, one converter ----------------------------
R = convert(A, "csr")
assert isinstance(R, CSR)
print("csr round-trip err:",
      np.abs(np.asarray(R.to_dense()) - np.asarray(A.to_dense())).max())

# --- index-expansion extension (outer-product assembly, §2.1) -----------
E = fsparse([[1], [2], [3]], [1, 2], 7.0, (3, 2))
print("expanded:\n", np.asarray(E.to_dense()))
