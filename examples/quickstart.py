"""Quickstart: Matlab-compatible sparse assembly in JAX, two-phase API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.sparse import CSR, convert, find, fsparse, nnz_of, ops, plan
from repro.core.oracle import dense_oracle

# --- the paper's running example (Listing 1), Matlab facade ------------
s = [4, 4, 5, 7, 3, 5, 5, 4, 3, 4, 9, 7, -2]
i = [3, 4, 1, 3, 2, 1, 4, 4, 4, 3, 2, 3, 1]
j = [3, 3, 1, 4, 1, 1, 4, 3, 1, 3, 2, 2, 4]

S = fsparse(i, j, s)                      # size implied, duplicates summed
print("dense:\n", np.asarray(S.to_dense()))
print("nnz:", nnz_of(S))
print("jcS:", np.asarray(S.indptr))       # [0 3 5 7 10] — as in §2.3.4
fi, fj, fv = find(S)                      # Matlab [i,j,v] = find(S)
print("find:", fi.tolist(), fj.tolist(), fv.tolist())

# --- two-phase API: plan once, assemble many ----------------------------
# The FEM workflow: the mesh (sparsity pattern) is fixed, element values
# change every step.  plan() runs the paper's Parts 1-4 once; assemble()
# is only the O(L) gather + collision-free scatter — no sorting.
rng = np.random.default_rng(0)
L, M, N = 50_000, 2_000, 1_500
rows = rng.integers(0, M, L).astype(np.int32)
cols = rng.integers(0, N, L).astype(np.int32)

pat = plan(rows, cols, (M, N))            # symbolic phase (once)
for step in range(3):                     # numeric phase (many times)
    vals = rng.normal(size=L).astype(np.float32)
    A = pat.assemble(vals)
    ref = dense_oracle(rows, cols, vals, M, N)
    err = np.abs(np.asarray(A.to_dense()) - ref).max()
    print(f"step {step}: reassembled nnz={int(A.nnz)}, "
          f"max err vs oracle {err:.2e}")

# batched numeric phase: many value vectors, one structure
vb = rng.normal(size=(4, L)).astype(np.float32)
Ab = pat.assemble_batch(vb)
print("batched data shape:", Ab.data.shape)

# --- one operator surface for every format: repro.sparse.ops ------------
x = jnp.ones((N,), jnp.float32)
y = ops.matmul(A, x)                      # spmv, dispatched per format
print("matmul check:", np.abs(np.asarray(y) - ref @ np.ones(N)).max())
T = ops.transpose(A)                      # CSC -> CSR: free reinterpret
diag_err = float(np.abs(np.asarray(ops.diagonal(A))
                        - np.diag(ref)[: min(M, N)]).max())
print("transpose:", type(T).__name__, T.shape, "diag err:", diag_err)
S3 = ops.add(A, ops.scale(A, 2.0))        # stays CSC; 3*A
print("add/scale err:",
      np.abs(np.asarray(S3.to_dense()) - 3 * np.asarray(A.to_dense())).max())

# --- differentiable assembly: grad flows through the cached plan --------
# the custom VJP is the O(L) gather-by-slot through the plan — no
# re-sort, no dense intermediate; works under jit/vmap too.
target = jnp.asarray(ref @ np.ones(N), jnp.float32)

def loss(v):
    return jnp.sum((ops.matmul(pat.assemble(v), x) - target) ** 2)

g = jax.jit(jax.grad(loss))(jnp.asarray(vals))
print("grad through assemble->matmul:", g.shape,
      "finite:", bool(jnp.all(jnp.isfinite(g))))

# --- accumarray-style duplicate handling --------------------------------
Smax = fsparse([1, 1, 2], [1, 1, 2], [2.0, 5.0, 3.0], (2, 2), accum="max")
print("accum='max' keeps the largest duplicate:",
      np.asarray(Smax.to_dense())[0, 0])

# --- format zoo: one protocol, one converter ----------------------------
R = convert(A, "csr")                     # direct CSC->CSR (one sort)
assert isinstance(R, CSR)
print("csr round-trip err:",
      np.abs(np.asarray(R.to_dense()) - np.asarray(A.to_dense())).max())

# --- index-expansion extension (outer-product assembly, §2.1) -----------
E = fsparse([[1], [2], [3]], [1, 2], 7.0, (3, 2))
print("expanded:\n", np.asarray(E.to_dense()))
