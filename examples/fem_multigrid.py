"""FEM multigrid setup — the Galerkin triple product as two-phase SpGEMM.

Geometric multigrid coarsens a fine-grid operator A through the
Galerkin projection  A_c = P' * A * P  with a fixed prolongation P
(linear interpolation here).  The *structures* of P and A come from
the mesh, so the product patterns of both SpGEMMs are fixed across
solver iterations — only A's values change (coefficient updates,
Newton linearizations, time steps).  That is exactly the plan-once /
refill-many split of :mod:`repro.sparse.spgemm`:

  symbolic phase (once)   product_plan(P', A) and product_plan(PtA, P)
  numeric phase (many)    ProductPattern.multiply — O(flops) gathers,
                          multiplies and one collision-free reduce

The demo builds the 1-D Poisson hierarchy, verifies A_c against the
dense oracle and against the classic stencil identity (Galerkin
coarsening of h^-1[-1, 2, -1] reproduces the coarse-grid stencil), and
re-fills the triple product for a coefficient sweep without re-running
any symbolic analysis.

    PYTHONPATH=src python examples/fem_multigrid.py [n]
"""
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.sparse import cached_product_plan, convert, ops, plan


def poisson_triplets(n: int, kappa: np.ndarray | None = None):
    """1-D P1 stiffness triplets of -(kappa u')' on n interior nodes.

    Element e spans nodes (e-1, e) with coefficient ``kappa[e]``; the
    per-element stiffness is kappa/h * [[1, -1], [-1, 1]] — four
    triplets per element, with boundary rows dropped (homogeneous
    Dirichlet).  The triplet *structure* is mesh-only, so a value sweep
    reuses one plan.
    """
    h = 1.0 / (n + 1)
    kappa = np.ones(n + 1) if kappa is None else kappa
    rows, cols, vals = [], [], []
    for e in range(n + 1):  # elements between nodes e-1 and e (0-offset)
        ke = kappa[e] / h
        for (a, b, s) in ((e - 1, e - 1, ke), (e, e, ke),
                          (e - 1, e, -ke), (e, e - 1, -ke)):
            if 0 <= a < n and 0 <= b < n:
                rows.append(a)
                cols.append(b)
                vals.append(s)
    return (np.array(rows, np.int32), np.array(cols, np.int32),
            np.array(vals, np.float64))


def prolongation_triplets(n_f: int):
    """Linear-interpolation P: (n_f, n_c) with n_c = (n_f - 1) // 2."""
    n_c = (n_f - 1) // 2
    rows, cols, vals = [], [], []
    for jc in range(n_c):
        jf = 2 * jc + 1  # fine node under coarse node jc
        rows += [jf - 1, jf, jf + 1]
        cols += [jc, jc, jc]
        vals += [0.5, 1.0, 0.5]
    return (np.array(rows, np.int32), np.array(cols, np.int32),
            np.array(vals, np.float64), n_c)


def main(n: int = 255):
    n_c = (n - 1) // 2
    print(f"fine grid: {n} nodes -> coarse grid: {n_c} nodes")

    # symbolic phase of the operands: mesh-fixed plans
    ra, ca, va = poisson_triplets(n)
    rp, cp, vp, _ = prolongation_triplets(n)
    pat_A = plan(ra, ca, (n, n))
    pat_P = plan(rp, cp, (n, n_c))
    A = pat_A.assemble(jnp.asarray(va, jnp.float32))
    P = pat_P.assemble(jnp.asarray(vp, jnp.float32))
    Pt = ops.transpose(P)  # zero-cost CSC -> CSR reinterpretation

    # Galerkin triple product: two cached SpGEMMs.  ops.matmul keys its
    # ProductPattern cache on both structures, so every later call with
    # the same mesh skips the symbolic phase entirely.
    t0 = time.perf_counter()
    A_c = ops.matmul(ops.matmul(Pt, A), P)
    jax.block_until_ready(A_c.data)
    t_first = time.perf_counter() - t0
    print(f"A_c = P' A P: nnz={int(A_c.nnz)} "
          f"(first call, symbolic + numeric: {t_first * 1e3:.1f} ms)")

    # oracle: dense triple product
    ref = np.asarray(ops.to_dense(Pt)) @ np.asarray(A.to_dense()) \
        @ np.asarray(ops.to_dense(P))
    np.testing.assert_allclose(np.asarray(A_c.to_dense()), ref,
                               rtol=1e-5, atol=1e-5)
    # classic identity: Galerkin coarsening of the uniform 1-D Poisson
    # stencil reproduces the coarse-grid stencil (up to the 2h scaling)
    d = np.diag(np.asarray(A_c.to_dense()))
    h_c = 2.0 / (n + 1)
    np.testing.assert_allclose(d, np.full(n_c, 2.0 / h_c), rtol=1e-5)
    print("A_c matches the dense oracle and the coarse stencil")

    # numeric refills: coefficient sweep, patterns fixed — the
    # repeated-assembly + repeated-product production loop
    vals_j = jnp.asarray(va, jnp.float32)
    t0 = time.perf_counter()
    sweeps = 0
    for kappa_scale in (0.5, 1.0, 4.0):
        Ak = pat_A.assemble(kappa_scale * vals_j)  # O(L) fill
        Ak_c = ops.matmul(ops.matmul(Pt, Ak), P)   # O(flops) refills
        jax.block_until_ready(Ak_c.data)
        sweeps += 1
        np.testing.assert_allclose(
            np.asarray(Ak_c.to_dense()), kappa_scale * ref,
            rtol=1e-5, atol=1e-4,
        )
    t_sweep = (time.perf_counter() - t0) / sweeps
    print(f"coefficient sweep: {sweeps} refills of P' A P, "
          f"{t_sweep * 1e3:.1f} ms each (no symbolic re-analysis; "
          f"first call was {t_first / max(t_sweep, 1e-9):.1f}x that)")

    # the same two plans, fetched explicitly (what ops.matmul cached)
    PtA = ops.matmul(Pt, A)
    pp2 = cached_product_plan(convert(PtA, "csc"), convert(P, "csc"))
    print(f"cached product plan: flops={pp2.flops}, "
          f"nnz(A_c)={int(np.asarray(pp2.pattern.nnz))}")
    print("OK")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 255)
