"""FEM Poisson solve — the paper's own motivating application (§1).

Assembles the P1 stiffness matrix of  -Δu = f  on the unit square
(structured triangulation, homogeneous Dirichlet BC) with the
two-phase API from raw element triplets (9 per triangle, heavy index
collisions = the paper's data-set regime), then solves with CG on the
padded-CSC SpMV.  Verifies against u = sin(πx)sin(πy).

The mesh is fixed, so the sparsity analysis (``plan``) runs ONCE; the
numeric fill (``SparsePattern.assemble``) is reused — here for a
coefficient sweep, in production for every load/time step.

    PYTHONPATH=src python examples/fem_poisson.py [n]
"""
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.sparse import ops, plan


def p1_triangle_triplets(n: int):
    """Stiffness triplets for a structured n x n triangulated grid."""
    h = 1.0 / n
    # vertices (n+1)^2; each cell -> two triangles
    vid = lambda ix, iy: iy * (n + 1) + ix
    rows, cols, vals = [], [], []
    bload = np.zeros((n + 1) * (n + 1))
    # reference P1 gradients on the two triangle orientations
    for ix in range(n):
        for iy in range(n):
            v00, v10 = vid(ix, iy), vid(ix + 1, iy)
            v01, v11 = vid(ix, iy + 1), vid(ix + 1, iy + 1)
            for tri in ((v00, v10, v01), (v11, v01, v10)):
                # local stiffness of a right isoceles triangle, leg h:
                # K = 1/2 * [[2,-1,-1],[-1,1,0],[-1,0,1]]
                K = 0.5 * np.array([[2, -1, -1], [-1, 1, 0], [-1, 0, 1]])
                for a in range(3):
                    for b in range(3):
                        rows.append(tri[a])
                        cols.append(tri[b])
                        vals.append(K[a, b])
                    bload[tri[a]] += h * h / 6.0  # lumped load of f=1-ish
    return (np.array(rows), np.array(cols), np.array(vals, np.float64),
            (n + 1) * (n + 1))


def main(n: int = 48):
    rows, cols, vals, nv = p1_triangle_triplets(n)
    print(f"mesh {n}x{n}: {nv} vertices, {len(rows)} raw triplets "
          f"(collisions ~{len(rows) / (7 * nv):.1f} per nnz)")

    # Dirichlet BC: move boundary rows/cols to identity via masking
    xs, ys = np.meshgrid(np.linspace(0, 1, n + 1), np.linspace(0, 1, n + 1))
    boundary = ((xs == 0) | (xs == 1) | (ys == 0) | (ys == 1)).ravel()
    keep = ~(boundary[rows] | boundary[cols])
    rows_i, cols_i, vals_i = rows[keep], cols[keep], vals[keep]
    # append identity for boundary nodes
    bidx = np.nonzero(boundary)[0]
    rows_f = np.concatenate([rows_i, bidx]) + 1
    cols_f = np.concatenate([cols_i, bidx]) + 1
    vals_f = np.concatenate([vals_i, np.ones(len(bidx))])

    # symbolic phase once (the mesh fixes the pattern) ...
    pat = plan(rows_f - 1, cols_f - 1, (nv, nv))
    # ... numeric phase per coefficient: a conductivity sweep reuses the
    # plan — only the O(L) gather/scatter runs, no sorting.
    vals_j = jnp.asarray(vals_f, jnp.float32)
    for kappa in (0.5, 2.0):
        Ak = pat.assemble(kappa * vals_j)
        print(f"  reassembled kappa={kappa}: nnz={int(Ak.nnz)} "
              f"(same structure, no re-sort)")
    A = pat.assemble(vals_j)
    print(f"assembled: nnz={int(A.nnz)} (from {len(rows_f)} triplets)")

    # rhs for u = sin(pi x) sin(pi y):  f = 2 pi^2 u, FE load ~ f h^2
    h = 1.0 / n
    u_exact = (np.sin(np.pi * xs) * np.sin(np.pi * ys)).ravel()
    f = 2 * np.pi**2 * u_exact * h * h
    f[boundary] = 0.0
    b = jnp.asarray(f, jnp.float32)

    # --- CG on the unified operator surface (ops.matmul
    #     dispatches per registered format; CSC here)
    @jax.jit
    def cg(b, iters=400):
        x = jnp.zeros_like(b)
        r = b - ops.matmul(A, x)
        p = r
        rs = jnp.dot(r, r)

        def body(carry, _):
            x, r, p, rs = carry
            Ap = ops.matmul(A, p)
            alpha = rs / jnp.maximum(jnp.dot(p, Ap), 1e-30)
            x = x + alpha * p
            r = r - alpha * Ap
            rs_new = jnp.dot(r, r)
            p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
            return (x, r, p, rs_new), rs_new

        (x, r, _, _), hist = jax.lax.scan(body, (x, r, p, rs), None,
                                          length=iters)
        return x, jnp.sqrt(hist[-1])

    u, res = cg(b)
    err = np.abs(np.asarray(u) - u_exact).max()
    print(f"CG residual {float(res):.2e}; max |u - u_exact| = {err:.4f} "
          f"(O(h^2) = {1.0 / n**2 * 4:.4f})")
    assert err < 10.0 / n ** 2 + 5e-2, "FEM solution out of tolerance"
    print("OK")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 48)
